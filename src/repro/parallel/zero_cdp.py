"""ZeRO-CDP (paper Sec. 4.4) on real registered architectures.

``core/zero.py`` demonstrates the schedule on a homogeneous toy stack; this
module is the production path behind ``--plan zero_cdp``: it works for ANY
architecture the model registry knows, by partitioning the *flattened*
parameter vector into N layer-group stages using
``models.model.param_stage_ids`` (embedding -> stage 0, stacked layer axes
-> even split, head/final norm -> stage N-1).

Layout
    The flattened parameters, ordered by layer-group stage id, form one
    stream that is cut into N equal contiguous chunks; data-parallel rank
    r persistently owns chunk r as an f32 master. The global state is a
    ``[N, chunk]`` array sharded over the data axis — parameters AND
    optimizer state live at Pp/N per rank (the ZeRO placement; boundaries
    are balanced by element count, so no rank idles on a short stage).

Streaming (forward)
    The chunks travel the ring point-to-point: N-1 ``lax.ppermute`` hops,
    one per tick; at tick t rank r holds stage (r - t) mod N and scatters
    it into its local reconstruction buffer. No collective broadcast — the
    HLO contains ``collective-permute`` ops where ZeRO-DP emits a
    per-stage ``all-gather`` (asserted in tests/test_parallel_plan.py).

Gradient merge (backward)
    ``jax.grad`` through the permute chain transposes it automatically:
    each rank's loss cotangent flows back along the reversed ring, and the
    contributions of ALL micro-batches to stage j accumulate at stage j's
    owner — the paper's "model states are communicated to a single GPU at
    the next time step", with no gradient collective at all.

Update rule
    The cyclic rotation makes parameters one step stale by the time the
    gradient lands (``cdp_v1``): the step streams theta_{t-1} while the
    owner updates theta_t. ``rule='dp'`` streams theta_t instead (exact DP
    numerics with ZeRO placement + point-to-point movement) — that variant
    anchors the parity test against plain DP.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.grad_sync import _ring_perm
from repro.core.schedule import RULE_CDP_V1
from repro.core.update_rules import needs_prev_params
from repro.models import model as model_mod
from repro.parallel.plan import ParallelPlan
from repro.sharding import specs as sh

PyTree = Any


# ---------------------------------------------------------------------------
# Static stage layout: flattened params -> N layer-group stage chunks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageSegment:
    """One contiguous run of a leaf's flat elements in a single stage."""
    leaf: int       # index in tree-flatten order
    start: int      # flat element range within the leaf
    stop: int
    stage: int
    offset: int     # element offset inside the stage's chunk


@dataclasses.dataclass(frozen=True)
class StageLayout:
    n: int
    chunk: int                       # elements per balanced stage chunk
    stage_sizes: tuple               # real (unpadded) elements per chunk
    segments: tuple                  # StageSegments, leaf-major order
    treedef: Any
    shapes: tuple
    dtypes: tuple

    @property
    def total(self) -> int:
        return sum(self.stage_sizes)


def _leading_stage_rows(sid: np.ndarray, shape: tuple):
    """Collapse a broadcastable stage-id array to (k, per-row stages) where
    the row index space is ``shape[:k]`` (k = last non-singleton id dim +1,
    covering both [L,1,..] and the double-stacked [P,per,1,..] layouts)."""
    k = max(i + 1 for i in range(sid.ndim) if sid.shape[i] > 1)
    rows = np.broadcast_to(sid.reshape(sid.shape[:k]), shape[:k]).ravel()
    return k, rows


@lru_cache(maxsize=8)
def build_stage_layout(cfg, n: int) -> StageLayout:
    """Partition ``cfg``'s parameter tree into ``n`` layer-group stages.

    Pure shape computation: parameters come from ``jax.eval_shape`` over
    ``init_params`` and stage assignments from ``param_stage_ids`` — stacked
    layer axes are split row-wise, so one leaf may span several stages.
    """
    shapes = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
    ids = model_mod.param_stage_ids(cfg, shapes, n)
    leaves, treedef = jax.tree.flatten(shapes)
    id_leaves = jax.tree.leaves(ids)

    raw = []                                     # (leaf, start, stop, stage)
    for li, (leaf, sid) in enumerate(zip(leaves, id_leaves)):
        sid_np = np.asarray(sid)
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        if sid_np.size == 1:
            raw.append((li, 0, size, int(sid_np.reshape(()))))
            continue
        k, rows = _leading_stage_rows(sid_np, leaf.shape)
        rest = size // int(np.prod(leaf.shape[:k]))
        run0 = 0
        for r in range(1, len(rows) + 1):
            if r == len(rows) or rows[r] != rows[run0]:
                raw.append((li, run0 * rest, r * rest, int(rows[run0])))
                run0 = r

    # Stage-id-major stream, BALANCED cut: concatenating the runs in
    # layer-group (stage-id) order preserves the paper's cyclic pipeline
    # order, but the raw groups are badly imbalanced (the embedding pins
    # most bytes to stage 0, short stacks leave stages empty). The stream
    # is therefore re-cut into N equal contiguous chunks — legal because
    # the supported update rules (dp / cdp_v1) apply uniform staleness, so
    # chunk boundaries carry no numerics, only ring-hop bytes. Per-leaf
    # run order stays monotonic in flat offset (stage ids increase with
    # the row index inside a leaf), which chunk/unchunk rely on.
    stage_major = [seg for st in range(n) for seg in raw if seg[3] == st]
    total = sum(b - a for _, a, b, _ in stage_major)
    chunk = max(-(-total // n), 1)
    segs = []
    g = 0                                        # offset in the stream
    for li, a, b, _ in stage_major:
        while a < b:
            c = g // chunk
            take = min(b - a, (c + 1) * chunk - g)
            segs.append(StageSegment(li, a, a + take, c, g - c * chunk))
            a += take
            g += take
    sizes = [max(0, min(chunk, total - j * chunk)) for j in range(n)]
    return StageLayout(
        n=n, chunk=chunk, stage_sizes=tuple(sizes),
        segments=tuple(segs), treedef=treedef,
        shapes=tuple(l.shape for l in leaves),
        dtypes=tuple(l.dtype for l in leaves))


def chunk_params(layout: StageLayout, params: PyTree) -> jnp.ndarray:
    """Params pytree -> [n, chunk] f32 master chunks (balanced cut of the
    stage-ordered stream; chunk j at row j)."""
    leaves = jax.tree.leaves(params)
    flats = [l.astype(jnp.float32).reshape(-1) for l in leaves]
    parts = [[] for _ in range(layout.n)]
    for s in layout.segments:                    # offsets follow this order
        parts[s.stage].append(flats[s.leaf][s.start:s.stop])
    rows = []
    for ps in parts:
        v = jnp.concatenate(ps) if ps else jnp.zeros((0,), jnp.float32)
        rows.append(jnp.pad(v, (0, layout.chunk - v.shape[0])))
    return jnp.stack(rows)


def unchunk_params(layout: StageLayout, stages: jnp.ndarray) -> PyTree:
    """[n, chunk] stage chunks -> params pytree (cast to each leaf dtype)."""
    pieces = [[] for _ in layout.shapes]
    for s in layout.segments:                    # leaf-major order
        pieces[s.leaf].append(stages[s.stage, s.offset:s.offset + s.stop - s.start])
    out = []
    for shape, dtype, ps in zip(layout.shapes, layout.dtypes, pieces):
        v = jnp.concatenate(ps) if len(ps) > 1 else ps[0]
        out.append(v.reshape(shape).astype(dtype))
    return jax.tree.unflatten(layout.treedef, out)


def params_from_state(cfg, state: PyTree, n: int) -> PyTree:
    """Materialise the full parameter pytree from a ZeRO-CDP train state
    (host-side: eval / export / comparison against a tree-layout run)."""
    layout = build_stage_layout(cfg, n)
    return unchunk_params(layout, state["params"]["stages"])


# ---------------------------------------------------------------------------
# Elastic re-cut: [n_old, chunk_old] -> [n_new, chunk_new]
# ---------------------------------------------------------------------------

def recut_chunks(layout_old: StageLayout, layout_new: StageLayout,
                 stages: np.ndarray) -> np.ndarray:
    """Re-cut one ``[n_old, chunk_old]`` chunk stack to the new layout
    WITHOUT a round-trip through the parameter pytree. The per-leaf flat
    buffers are reassembled from the old segments and re-sliced by the new
    ones, which handles the two traps a naive stream split would hit:
    stage assignment depends on n (``param_stage_ids(cfg, shapes, n)``
    reorders the stream), and ``unchunk_params`` casts to each leaf's
    dtype — fatal for f32 optimizer slots of a bf16 parameter. Here the
    arrays never leave the chunk dtype. Host-side numpy: recovery and
    rejoin run between steps, not inside jit."""
    if layout_old.treedef != layout_new.treedef:
        raise ValueError("recut_chunks: layouts describe different "
                         "parameter trees")
    stages = np.asarray(stages)
    if stages.shape != (layout_old.n, layout_old.chunk):
        raise ValueError(
            f"recut_chunks: expected [{layout_old.n}, {layout_old.chunk}] "
            f"chunks, got {stages.shape}")
    pieces = [[] for _ in layout_old.shapes]
    for s in layout_old.segments:                # leaf-major order
        pieces[s.leaf].append(
            stages[s.stage, s.offset:s.offset + s.stop - s.start])
    flats = [np.concatenate(ps) if len(ps) > 1 else ps[0] for ps in pieces]
    parts = [[] for _ in range(layout_new.n)]
    for s in layout_new.segments:                # offsets follow this order
        parts[s.stage].append(flats[s.leaf][s.start:s.stop])
    rows = []
    for ps in parts:
        v = np.concatenate(ps) if ps else np.zeros((0,), stages.dtype)
        rows.append(np.pad(v, (0, layout_new.chunk - v.shape[0])))
    return np.stack(rows)


def recut_stage_state(cfg, state: PyTree, n_old: int, n_new: int) -> PyTree:
    """Re-cut a whole ZeRO-CDP train state across a ring resize: every
    ``[n_old, chunk_old]`` leaf (master chunks, ``params_prev``, optimizer
    slots — matched by shape, not by key, so new optimizers' slots recut
    too) moves to the ``n_new`` layout; scalars (``step``, adamw's ``t``)
    pass through untouched. Input and output are host trees."""
    lo = build_stage_layout(cfg, n_old)
    ln = build_stage_layout(cfg, n_new)

    def one(x):
        arr = np.asarray(x)
        if arr.shape == (lo.n, lo.chunk):
            return recut_chunks(lo, ln, arr)
        return arr

    return jax.tree.map(one, state)


# ---------------------------------------------------------------------------
# The point-to-point stage ring
# ---------------------------------------------------------------------------

def stream_stages(my_chunk: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """Cyclic parameter streaming inside a shard_map manual over ``axis``.

    ``my_chunk`` is this rank's stage (stage index == rank index). N-1
    unrolled ``ppermute`` hops move every chunk one neighbour per tick — at
    tick t rank r holds stage (r - t) mod N and scatters it into its local
    [n, chunk] reconstruction. Each hop is a distinct ``collective-permute``
    HLO op; the transpose (gradient path) is the reversed ring, which
    accumulates every micro-batch's stage-j gradient at stage j's owner.
    """
    r = jax.lax.axis_index(axis)
    perm = _ring_perm(n)
    out = jnp.zeros((n,) + my_chunk.shape, my_chunk.dtype)
    buf = my_chunk
    for t in range(n):
        j = jax.lax.rem(r - t + n, n)
        out = jax.lax.dynamic_update_slice(out, buf[None], (j, 0))
        if t < n - 1:
            buf = jax.lax.ppermute(buf, axis, perm)
    return out


# ---------------------------------------------------------------------------
# Train-state plumbing (called by core.trainer under placement=stage_sharded)
# ---------------------------------------------------------------------------

def init_stage_state(cfg, plan: ParallelPlan, params: PyTree, opt,
                     n: int) -> PyTree:
    layout = build_stage_layout(cfg, n)
    chunks = {"stages": chunk_params(layout, params)}
    state = {"params": chunks, "opt": opt.init(chunks),
             "step": jnp.zeros((), jnp.int32)}
    if needs_prev_params(plan.rule):
        state["params_prev"] = jax.tree.map(jnp.copy, chunks)
    return state


def make_train_step(cfg, trainer, plan: ParallelPlan, mesh, opt,
                    loss_fn: Optional[Callable] = None):
    """Builds the ZeRO-CDP train_step(state, batch) -> (state, metrics).

    Returns the bare step function; ``core.trainer.make_train_step`` wraps
    it into the public (step, state_sharding_fn, batch_sharding_fn) triple
    (trainer owns the placement specs for every plan). ``trainer`` is the
    TrainerConfig (axes / lr / clip knobs)."""
    axis = trainer.data_axis
    n = mesh.shape[axis]
    # plan/mesh validation is core.trainer.make_train_step's job (the one
    # authoritative call, with the trainer's axis names)
    if trainer.seq_parallel:
        raise ValueError(
            "seq_parallel is not supported with stage-streamed plans "
            "(the reconstruction runs outside the activation-sharding "
            "scope); drop it or pick a tree-layout plan")
    layout = build_stage_layout(cfg, n)
    loss_fn = loss_fn or (lambda p, b: model_mod.loss_fn(cfg, p, b))
    lr_fn = trainer.lr_schedule or (lambda s: 1e-3)
    comm_dtype = jnp.dtype(trainer.grad_comm_dtype)
    use_prev = needs_prev_params(plan.rule)
    assert plan.rule in ("dp", RULE_CDP_V1)

    def grad_shard(src_chunk, batch):
        # src_chunk: [1, chunk] — this rank's stage of theta_{t-1} (cdp_v1)
        # or theta_t (dp). Differentiating through the streaming chain makes
        # the transposed ring deliver sum_r dL_r/d(stage) to the owner.
        # Chunks travel the ring in grad_comm_dtype (both directions: the
        # transpose of the cast is a cast); the f32 master stays local.
        def local_loss(my, b):
            streamed = stream_stages(my[0].astype(comm_dtype), axis, n)
            params = unchunk_params(layout, streamed)
            return loss_fn(params, b)

        (loss, metrics), g = jax.value_and_grad(local_loss, has_aux=True)(
            src_chunk, batch)
        g = g / n                              # transpose sums; want the mean
        return g, jax.lax.pmean(loss, axis), jax.lax.pmean(metrics, axis)

    def train_step(state, batch):
        chunks = state["params"]["stages"]
        src = state["params_prev"]["stages"] if use_prev else chunks
        grads, loss, metrics = compat.shard_map(
            grad_shard, mesh=mesh,
            in_specs=(P(axis, None), sh.batch_manual_pspecs(batch, (axis,))),
            out_specs=(P(axis, None), P(), P()),
            axis_names={axis}, check_vma=False)(src, batch)
        if trainer.grad_clip:
            gnorm = jnp.sqrt(jnp.sum(grads.astype(jnp.float32) ** 2))
            scale = jnp.minimum(1.0, trainer.grad_clip / (gnorm + 1e-9))
            grads = grads * scale
        lr = lr_fn(state["step"])
        new_chunks, new_opt = opt.update({"stages": grads}, state["opt"],
                                         {"stages": chunks}, lr)
        new_state = {"params": new_chunks, "opt": new_opt,
                     "step": state["step"] + 1}
        if use_prev:
            new_state["params_prev"] = {"stages": chunks}
        metrics = dict(metrics)
        metrics["lr"] = lr
        return new_state, metrics

    return train_step
