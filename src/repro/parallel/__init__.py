"""Parallelism strategies as first-class plans.

``ParallelPlan`` + the strategy registry (``dp``, ``cdp_v1``, ``cdp_v2``,
``cdp_random``, ``zero1_ring``, ``zero_cdp``) live in ``plan`` (jax-free so
launchers can enumerate ``--plan`` choices before device init); the
ZeRO-CDP stage-streaming execution path lives in ``zero_cdp`` (imported
lazily by the trainer — do not import it here).
"""
from repro.parallel.plan import (PLACE_REPLICATED, PLACE_STAGE_SHARDED,
                                 PLACE_ZERO1, PLAN_REGISTRY, SYNC_PSUM,
                                 SYNC_RING, SYNC_STREAM, SYNC_ZERO1_RING,
                                 ParallelPlan, available_plans, get_plan,
                                 plan_from_legacy_flags, plan_help,
                                 register_plan, resolve_plan)

__all__ = [
    "ParallelPlan", "PLAN_REGISTRY", "available_plans", "get_plan",
    "plan_from_legacy_flags", "plan_help", "register_plan", "resolve_plan",
    "SYNC_PSUM", "SYNC_RING", "SYNC_STREAM", "SYNC_ZERO1_RING",
    "PLACE_REPLICATED", "PLACE_ZERO1", "PLACE_STAGE_SHARDED",
]
