"""First-class parallelism plans: the paper's *choice of schedule* as one
composable object instead of a soup of ``TrainerConfig`` flags.

A :class:`ParallelPlan` names the three decisions every strategy in the
paper makes:

  * ``rule``      — the u_{i,j} update rule (which theta each micro-batch
                    differentiates at): ``dp`` | ``cdp_v1`` | ``cdp_v2`` |
                    ``cdp_random`` (see ``repro.core.schedule``);
  * ``sync``      — the gradient-merge / parameter-movement implementation:
                    ``psum`` (baseline all-reduce burst), ``ring`` (the CDP
                    balanced point-to-point ring), ``zero1_ring`` (ring
                    reduce-scatter + sharded optimizer + param all-gather),
                    ``stream`` (ZeRO-CDP stage streaming, Sec. 4.4);
  * ``placement`` — where parameters/optimizer state live: ``replicated``,
                    ``zero1`` (data-sharded optimizer slots), or
                    ``stage_sharded`` (each rank persistently owns one
                    layer-group stage — the ZeRO memory layout).

The registry maps strategy names to plans exactly the way
``repro.kernels.registry`` maps op names to kernel backends; the deprecated
``TrainerConfig`` flags (``rule=``, ``ring_grads=``, ``zero1_ring=``,
``zero_axis=``) resolve onto a plan via :func:`plan_from_legacy_flags`, the
same pattern ``attn_backend`` uses for the kernel registry.

This module is dependency-light on purpose (no jax import): launchers list
``available_plans()`` for ``--plan`` help before jax initialises devices.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple, Union

from repro.core.schedule import (ALL_RULES, RULE_CDP_RANDOM, RULE_CDP_V1,
                                 RULE_CDP_V2, RULE_DP)

# Gradient-sync / parameter-movement implementations (owned by
# repro.core.grad_sync and repro.parallel.zero_cdp).
SYNC_PSUM = "psum"
SYNC_RING = "ring"
SYNC_ZERO1_RING = "zero1_ring"
SYNC_STREAM = "stream"
SYNCS = (SYNC_PSUM, SYNC_RING, SYNC_ZERO1_RING, SYNC_STREAM)

# Parameter / optimizer-state placement.
PLACE_REPLICATED = "replicated"
PLACE_ZERO1 = "zero1"
PLACE_STAGE_SHARDED = "stage_sharded"
PLACEMENTS = (PLACE_REPLICATED, PLACE_ZERO1, PLACE_STAGE_SHARDED)

# Rules the single-stream ZeRO-CDP path supports: ``cdp_v1`` (every stage
# one step stale — the delay the cyclic parameter rotation induces) and
# ``dp`` (no staleness; streaming becomes a point-to-point re-materialise
# of theta_t). ``cdp_v2``'s per-rank fresh/stale mix would need BOTH
# parameter versions on the ring (2x volume) — not implemented.
STREAM_RULES = (RULE_DP, RULE_CDP_V1)


@dataclass(frozen=True)
class ParallelPlan:
    """One parallelism strategy: update rule + gradient sync + placement.

    ``zero_axis`` optionally names a mesh axis over which large 2D weights
    are additionally FSDP-sharded (GSPMD inserts the per-layer all-gathers).
    ``n_stages`` optionally pins the ZeRO-CDP stage count: the stage ring is
    always the data axis (chunk storage is sharded over it), so a non-zero
    pin is a fail-fast assertion in :meth:`validate_mesh`, not a resize.
    """
    name: str
    rule: str = RULE_CDP_V2
    sync: str = SYNC_RING
    placement: str = PLACE_REPLICATED
    zero_axis: Optional[str] = None
    n_stages: int = 0
    min_data: int = 1
    description: str = ""

    def with_(self, **kw) -> "ParallelPlan":
        return replace(self, **kw).validate()

    def validate(self) -> "ParallelPlan":
        if self.rule not in ALL_RULES:
            raise ValueError(
                f"plan {self.name!r}: unknown rule {self.rule!r}; "
                f"one of {ALL_RULES}")
        if self.sync not in SYNCS:
            raise ValueError(
                f"plan {self.name!r}: unknown sync {self.sync!r}; "
                f"one of {SYNCS}")
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"plan {self.name!r}: unknown placement {self.placement!r}; "
                f"one of {PLACEMENTS}")
        if (self.sync == SYNC_STREAM) != (self.placement == PLACE_STAGE_SHARDED):
            raise ValueError(
                f"plan {self.name!r}: stage streaming and stage-sharded "
                "placement imply each other (sync='stream' <-> "
                "placement='stage_sharded')")
        if (self.sync == SYNC_ZERO1_RING) != (self.placement == PLACE_ZERO1):
            raise ValueError(
                f"plan {self.name!r}: the ZeRO-1 ring implies zero1 "
                "placement (sync='zero1_ring' <-> placement='zero1')")
        if self.sync == SYNC_STREAM and self.rule not in STREAM_RULES:
            raise ValueError(
                f"plan {self.name!r}: ZeRO-CDP parameter streaming supports "
                f"rule in {STREAM_RULES} (cdp_v2 would need both parameter "
                "versions on the ring)")
        if self.placement == PLACE_STAGE_SHARDED and self.zero_axis:
            raise ValueError(
                f"plan {self.name!r}: zero_axis has no effect on a "
                "stage-sharded plan (params AND optimizer state are already "
                "fully sharded over the data axis)")
        return self

    def validate_mesh(self, mesh, data_axis: str = "data",
                      pod_axis: Optional[str] = None) -> "ParallelPlan":
        """Fail fast on a plan/mesh mismatch (before any tracing)."""
        n = mesh.shape[data_axis]
        if n < self.min_data:
            raise ValueError(
                f"plan {self.name!r} needs a {data_axis!r} axis of >= "
                f"{self.min_data} (got {n}); stage cycling degenerates on a "
                "single rank")
        if self.placement == PLACE_STAGE_SHARDED:
            if self.n_stages and self.n_stages != n:
                raise ValueError(
                    f"plan {self.name!r}: n_stages={self.n_stages} must "
                    f"equal the {data_axis!r} axis size {n} (stage chunks "
                    "are sharded over it)")
            if pod_axis:
                raise ValueError(
                    f"plan {self.name!r} does not compose with a pod axis "
                    "yet (the stage ring spans exactly the data axis)")
        return self

    def validate_resize(self, n_old: int, n_new: int) -> "ParallelPlan":
        """Fail fast on an elastic ring resize (shrink after a rank death,
        grow on rejoin) the plan cannot survive — BEFORE any state has
        been re-cut or a mesh rebuilt."""
        if n_new < max(self.min_data, 1):
            raise ValueError(
                f"plan {self.name!r}: cannot re-form at {n_new} rank(s) "
                f"(needs >= {max(self.min_data, 1)}); the survivors can "
                "only resume from a checkpoint on a fresh mesh")
        if self.n_stages and self.n_stages != n_new:
            raise ValueError(
                f"plan {self.name!r}: n_stages={self.n_stages} is pinned, "
                f"which forbids an elastic resize {n_old} -> {n_new}")
        return self


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PLAN_REGISTRY: Dict[str, ParallelPlan] = {}


def register_plan(plan: ParallelPlan) -> ParallelPlan:
    PLAN_REGISTRY[plan.name] = plan.validate()
    return plan


def available_plans() -> Tuple[str, ...]:
    return tuple(sorted(PLAN_REGISTRY))


def get_plan(name: str) -> ParallelPlan:
    if name not in PLAN_REGISTRY:
        raise ValueError(
            f"unknown parallel plan {name!r}; one of {available_plans()}")
    return PLAN_REGISTRY[name]


def resolve_plan(value: Union[ParallelPlan, str, None],
                 default: str = RULE_CDP_V2) -> ParallelPlan:
    """Normalise user input (plan object | registered name | None)."""
    if value is None:
        return get_plan(default)
    if isinstance(value, ParallelPlan):
        return value.validate()
    if isinstance(value, str):
        return get_plan(value)
    raise TypeError(
        f"cannot resolve a ParallelPlan from {type(value).__name__}")


def plan_from_legacy_flags(rule: Optional[str] = None,
                           ring_grads: Optional[bool] = None,
                           zero1_ring: Optional[bool] = None,
                           zero_axis: Optional[str] = None) -> ParallelPlan:
    """The plan the deprecated ``TrainerConfig`` flag combination meant.

    Mirrors the pre-plan dispatch exactly: ``zero1_ring`` wins over the
    merge choice; ``rule='dp'`` or ``ring_grads=False`` collapse the ring
    to the psum all-reduce; everything else rides the CDP ring.
    """
    rule = rule or RULE_CDP_V2
    if zero1_ring:
        base = get_plan("zero1_ring").with_(rule=rule)
    elif rule == RULE_DP or ring_grads is False:
        base = get_plan(rule) if rule == RULE_DP else ParallelPlan(
            name=f"{rule}+psum", rule=rule, sync=SYNC_PSUM,
            description=f"{rule} update rule, collective all-reduce merge")
    else:
        base = get_plan(rule)
    if zero_axis:
        base = base.with_(zero_axis=zero_axis)
    return base.validate()


# ---------------------------------------------------------------------------
# The paper's strategies (Table 1 rows that map onto pure data parallelism)
# ---------------------------------------------------------------------------

register_plan(ParallelPlan(
    name="dp", rule=RULE_DP, sync=SYNC_PSUM,
    description="baseline Data Parallelism: every rank differentiates at "
                "theta_t; one all-reduce burst merges gradients"))
register_plan(ParallelPlan(
    name="cdp_v1", rule=RULE_CDP_V1, sync=SYNC_RING,
    description="CDP-v1: all stages differentiate at theta_{t-1}; gradients "
                "merge on the point-to-point ring"))
register_plan(ParallelPlan(
    name="cdp_v2", rule=RULE_CDP_V2, sync=SYNC_RING,
    description="CDP-v2 (paper default): stage-wise theta_t/theta_{t-1} mix "
                "per u_{i,j}; ring gradient merge"))
register_plan(ParallelPlan(
    name="cdp_random", rule=RULE_CDP_RANDOM, sync=SYNC_RING,
    description="beyond-paper: per-step random freshness threshold between "
                "cdp_v2 and cdp_v1; ring merge"))
register_plan(ParallelPlan(
    name="zero1_ring", rule=RULE_CDP_V2, sync=SYNC_ZERO1_RING,
    placement=PLACE_ZERO1,
    description="ring reduce-scatter + data-sharded optimizer state + "
                "parameter all-gather (ZeRO-1 on the CDP ring)"))
register_plan(ParallelPlan(
    name="zero_cdp", rule=RULE_CDP_V1, sync=SYNC_STREAM,
    placement=PLACE_STAGE_SHARDED, min_data=2,
    description="ZeRO-CDP (paper Sec. 4.4): parameters stage-sharded over "
                "the data axis, streamed point-to-point with "
                "collective-permute instead of the ZeRO-DP all-gather; "
                "gradient chunks return to their owner rank through the "
                "transposed ring"))


def plan_help() -> str:
    """One line per registered plan (CLI ``--plan`` help text)."""
    return "; ".join(f"{n}: {PLAN_REGISTRY[n].description}"
                     for n in available_plans())
